"""End-to-end disaggregated serving: real model, real bytes, failures.

The critical assertion: generation through the FULL disaggregated path
(prefill worker → KVDirect one-sided pull → decode worker) produces the
SAME tokens as running the model monolithically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.serving.disagg import DisaggService
from repro.serving.request import RequestState


@pytest.fixture(scope="module")
def service_setup():
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def monolithic_generate(model, params, tokens, n):
    logits, state = model.prefill(params, {"tokens": jnp.asarray(tokens[None])},
                                  remat=False)
    out = [int(jnp.argmax(logits[0, : model.cfg.vocab_size]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n):
        logits, state = model.decode_step(params, state, tok)
        tok = jnp.argmax(logits[:, : model.cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


class TestDisaggEndToEnd:
    def test_matches_monolithic_generation(self, service_setup):
        cfg, model, params = service_setup
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        ref = monolithic_generate(model, params, tokens, 4)

        svc = DisaggService(model, params, n_prefill=1, num_blocks=64)
        req = svc.submit(tokens)
        got = svc.generate(req, max_new=4)
        assert got == ref, f"disagg {got} != monolithic {ref}"
        assert req.state == RequestState.DONE

    def test_complete_frees_prefill_blocks(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, num_blocks=64)
        rng = np.random.default_rng(1)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        req = svc.submit(tokens)
        w = svc.prefills[req.prefill_worker]
        held = w.pool.stats.in_use
        assert held > 0
        svc.generate(req, max_new=2)
        assert w.pool.stats.in_use == 0  # COMPLETE() released them

    def test_prefill_worker_failure_recovers(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, num_blocks=64)
        rng = np.random.default_rng(2)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        ref = monolithic_generate(model, params, tokens, 3)

        req = svc.submit(tokens)
        victim = req.prefill_worker
        svc.fail_prefill_worker(victim)          # crash before the pull
        assert req.prefill_worker != victim       # re-prefilled elsewhere
        assert req.retries == 1
        got = svc.generate(req, max_new=3)
        assert got == ref

    def test_elastic_scale_up_serves_new_worker(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, num_blocks=64)
        new_wid = svc.add_prefill_worker(num_blocks=64)
        assert new_wid in svc.conn_mgr.peers  # auto-CONNECTed, no restart
        # saturate worker p0's accounting so the new worker is chosen
        svc.prefills["p0"].pool.allocate(60)
        rng = np.random.default_rng(3)
        tokens = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
        req = svc.submit(tokens)
        assert req.prefill_worker == new_wid
        out = svc.generate(req, max_new=2)
        assert len(out) == 3
