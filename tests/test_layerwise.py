"""Layer-streamed decode consumption (``consume="layerwise"``).

Three layers of guarantees:

1. ``TransferFuture.wait_layer`` — the consumer's synchronization
   primitive: progresses the engine exactly until the requested layer's
   reads executed, raises typed ``ConnectionTornError`` when the pull is
   torn down (including BETWEEN layers), and fails loudly on untagged
   pulls / bad layer indices.
2. Equivalence — ``consume="layerwise"`` and full-pull decode produce
   BIT-IDENTICAL logits and tokens (models are built with ``unroll=True``
   so both paths run the same python-loop per-op math; the scan path is
   numerically equivalent but XLA schedules it differently), across batch
   sizes and margin (``max_new``) settings.  CPU-only, no pallas.
3. Fault injection — a teardown injected between layer completions
   mid-``decode_round`` fails the torn request's future with the right
   ``request_id``, parks the request (or re-routes it when capacity
   exists), leaves co-batched survivors' tokens unchanged, and
   ``retry_parked`` replays it to a healthy worker with identical output.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.descriptors import ByteRange, CompleteTxn, ReadTxn
from repro.core.transfer_engine import (
    ConnectionTornError,
    MemoryRegion,
    TransferEngine,
)
from repro.models.transformer import DecoderLM
from repro.serving.disagg import DisaggService
from repro.serving.engine import DecodeWorker
from repro.serving.request import RequestState

DST_BASE = 1 << 20
PAGE = 4096


def make_engine():
    eng = TransferEngine()
    src = np.arange(64 * 1024, dtype=np.uint8) % 251
    dst = np.zeros(64 * 1024, dtype=np.uint8)
    eng.register_memory(MemoryRegion("p0", 0, src))
    eng.register_memory(MemoryRegion("d0", DST_BASE, dst))
    return eng, src, dst


def layered_pull(rid: str, n_layers: int, blocks_per_layer: int = 2):
    """The txn shape pull_kv emits: layer-ordered reads, COMPLETE last."""
    txns = []
    for layer in range(n_layers):
        for b in range(blocks_per_layer):
            off = (layer * blocks_per_layer + b) * PAGE
            txns.append(ReadTxn(rid, "p0", "d0", ByteRange(off, PAGE),
                                ByteRange(DST_BASE + off, PAGE), layer=layer))
    txns.append(CompleteTxn(rid, "p0", "d0"))
    return txns


class TestWaitLayer:
    def test_progresses_only_until_the_layer_lands(self):
        eng, src, dst = make_engine()
        (fut,) = eng.submit(layered_pull("r1", n_layers=3))
        fut.wait_layer(0, budget=1)
        assert fut.layer_done(0) and not fut.layer_done(1)
        assert eng.pending > 0 and not fut.done()
        # layer-0 bytes are already byte-exact in the destination
        np.testing.assert_array_equal(dst[: 2 * PAGE], src[: 2 * PAGE])
        fut.wait_layer(2)
        assert fut.layers_done == (0, 1, 2)

    def test_noop_on_already_done_layer(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit(layered_pull("r1", n_layers=2))
        eng.drain()
        assert fut.done()
        fut.wait_layer(1)  # resolved future: returns immediately

    @pytest.mark.parametrize("torn_worker", ["p0", "d0"])
    def test_teardown_between_layers_raises_typed(self, torn_worker):
        eng, _, _ = make_engine()
        (fut,) = eng.submit(layered_pull("rX", n_layers=3))
        fut.wait_layer(0, budget=1)
        eng.deregister_memory(torn_worker)  # between layer 0 and layer 1
        with pytest.raises(ConnectionTornError) as ei:
            fut.wait_layer(1)
        assert ei.value.request_ids == ("rX",)
        assert fut.failed

    def test_bad_layer_index_raises_runtimeerror(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit(layered_pull("r1", n_layers=2))
        with pytest.raises(RuntimeError, match="layer 7"):
            fut.wait_layer(7)

    def test_untagged_pull_raises_runtimeerror(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit([
            ReadTxn("r1", "p0", "d0", ByteRange(0, PAGE),
                    ByteRange(DST_BASE, PAGE)),
            CompleteTxn("r1", "p0", "d0")])
        with pytest.raises(RuntimeError, match="untagged"):
            fut.wait_layer(0)

    def test_layer_callbacks_fire_in_order_and_late_registration(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit(layered_pull("r1", n_layers=3))
        seen = []
        fut.add_layer_callback(lambda f, l: seen.append(l))
        fut.wait_layer(1, budget=1)
        assert seen == [0, 1]
        late = []
        fut.add_layer_callback(lambda f, l: late.append(l))  # fires for done
        assert late == [0, 1]
        eng.drain()
        assert seen == late == [0, 1, 2]


# ---------------------------------------------------------------- models
@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("deepseek-67b")
    # unroll=True: decode_step runs layers as a python loop, the same
    # per-op math as decode_step_layerwise — bit-identity is exact.
    model = DecoderLM(cfg, unroll=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_smoke_config("llama4-maverick-400b-a17b")  # grouped layers
    model = DecoderLM(cfg, unroll=True)
    params = model.init_params(jax.random.PRNGKey(1))
    return cfg, model, params


def monolithic_generate(model, params, tokens, n):
    logits, state = model.prefill(params, {"tokens": jnp.asarray(tokens[None])},
                                  remat=False)
    out = [int(jnp.argmax(logits[0, : model.cfg.vocab_size]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n):
        logits, state = model.decode_step(params, state, tok)
        tok = jnp.argmax(logits[:, : model.cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


class TestModelLevelEquivalence:
    @pytest.mark.parametrize("batch", [1, 2, 4])
    @pytest.mark.parametrize("margin", [1, 2])
    def test_layerwise_step_bit_identical(self, dense_setup, batch, margin):
        cfg, model, params = dense_setup
        rng = np.random.default_rng(batch * 10 + margin)
        toks = rng.integers(0, cfg.vocab_size, (batch, 64)).astype(np.int32)
        logits, state = model.prefill(params, {"tokens": jnp.asarray(toks)},
                                      remat=False, max_blocks_margin=margin)
        t = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        l_full, s_full = model.decode_step(params, state, t)
        l_lw, s_lw = model.decode_step_layerwise(
            params, state, t, lambda l: (state.k_pages[l], state.v_pages[l]))
        np.testing.assert_array_equal(np.asarray(l_full), np.asarray(l_lw))
        np.testing.assert_array_equal(np.asarray(s_full.k_pages),
                                      np.asarray(s_lw.k_pages))
        np.testing.assert_array_equal(np.asarray(s_full.v_pages),
                                      np.asarray(s_lw.v_pages))
        # the layerwise state feeds the NEXT (full) step bit-identically
        t2 = jnp.argmax(l_full[:, : cfg.vocab_size].astype(jnp.float32),
                        axis=-1).astype(jnp.int32)
        l2_full, _ = model.decode_step(params, s_full, t2)
        l2_lw, _ = model.decode_step(params, s_lw, t2)
        np.testing.assert_array_equal(np.asarray(l2_full), np.asarray(l2_lw))

    def test_layerwise_step_bit_identical_grouped_moe(self, moe_setup):
        cfg, model, params = moe_setup
        assert model.group > 1  # interleaved MoE: the scan unit is a group
        rng = np.random.default_rng(7)
        toks = rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32)
        logits, state = model.prefill(params, {"tokens": jnp.asarray(toks)},
                                      remat=False, max_blocks_margin=1)
        t = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        l_full, s_full = model.decode_step(params, state, t)
        l_lw, s_lw = model.decode_step_layerwise(
            params, state, t, lambda l: (state.k_pages[l], state.v_pages[l]))
        np.testing.assert_array_equal(np.asarray(l_full), np.asarray(l_lw))
        np.testing.assert_array_equal(np.asarray(s_full.k_pages),
                                      np.asarray(s_lw.k_pages))

    def test_rejects_non_paged_archs(self, dense_setup):
        _, model, params = dense_setup
        cfg = get_smoke_config("mamba2-780m")
        ssm = DecoderLM(cfg)
        p = ssm.init_params(jax.random.PRNGKey(0))
        state = ssm.decode_state_shape(1, 32)
        with pytest.raises(NotImplementedError, match="paged"):
            ssm.decode_step_layerwise(p, state, jnp.zeros((1,), jnp.int32),
                                      lambda l: (None, None))


class TestServiceEquivalence:
    @pytest.mark.parametrize("n_requests", [1, 3])
    @pytest.mark.parametrize("max_new", [1, 4])  # margin_blocks = ceil(max_new/bs)
    def test_layerwise_matches_full_and_monolithic(self, dense_setup,
                                                   n_requests, max_new):
        cfg, model, params = dense_setup
        rng = np.random.default_rng(n_requests * 100 + max_new)
        toks = [rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
                for _ in range(n_requests)]
        results = {}
        for mode in ("full", "layerwise"):
            svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                                num_blocks=64, consume=mode)
            reqs = [svc.submit(t) for t in toks]
            got = svc.generate_many(reqs, max_new=max_new)
            results[mode] = [got[r.request_id] for r in reqs]
            assert all(r.state is RequestState.DONE for r in reqs)
            assert not svc.pending
        assert results["full"] == results["layerwise"]
        for i, t in enumerate(toks):
            assert results["layerwise"][i] == \
                monolithic_generate(model, params, t, max_new)

    def test_streaming_step_overlaps_the_pull(self, dense_setup):
        """The tentpole's point: the first decode step's early-layer
        attention must run while the pull still has transactions queued."""
        cfg, model, params = dense_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, consume="layerwise")
        rng = np.random.default_rng(0)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        svc.admit_queued()
        pending_at_layer = []
        orig = model.decode_step_layerwise

        def spy(params_, state, toks, fetch):
            return orig(params_, state, toks,
                        lambda l: (pending_at_layer.append((l, svc.engine.pending)),
                                   fetch(l))[1])

        model.decode_step_layerwise = spy
        try:
            out = svc.decode.decode_round(2, pump_budget=4)
        finally:
            model.decode_step_layerwise = orig
        assert req.request_id in out
        assert pending_at_layer[0][0] == 0
        assert pending_at_layer[0][1] > 0, \
            "pull fully drained before the first layer's attention — no overlap"

    def test_full_worker_ignores_inflight_until_complete(self, dense_setup):
        """consume='full' keeps the PR 2 contract: an in-flight admission
        is NOT decoded until its future resolves."""
        cfg, model, params = dense_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, consume="full")
        rng = np.random.default_rng(1)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        svc.admit_queued()
        assert svc.decode.inflight and not svc.decode.resident
        out = svc.decode.decode_round(1, pump_budget=1)  # one pump, no decode
        assert out == {} or req.request_id not in out


class TestFaultInjectionBetweenLayers:
    def _tokens(self, cfg, seed=2):
        return np.random.default_rng(seed).integers(
            0, cfg.vocab_size, 64).astype(np.int32)

    def test_tear_between_layers_reroutes_with_identical_tokens(self, dense_setup):
        cfg, model, params = dense_setup
        tokens = self._tokens(cfg)
        ref = monolithic_generate(model, params, tokens, 3)
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64, consume="layerwise")
        req = svc.submit(tokens)
        victim = req.prefill_worker
        svc.admit_queued()
        fut = svc.decode.inflight[req.request_id].future
        torn = []

        def tear_at_layer_1(f, layer):
            torn.append(layer)
            if layer == 1:
                svc.fail_prefill_worker(victim)

        fut.add_layer_callback(tear_at_layer_1)
        got = svc.generate_many([req], max_new=3)
        # the tear fired between layer completions, failed the right
        # request, and failover replayed it on the surviving prefill
        assert torn[:2] == [0, 1]
        assert fut.failed
        err = fut.exception()
        assert isinstance(err, ConnectionTornError)
        assert err.request_ids == (req.request_id,)
        assert req.prefill_worker != victim
        assert req.retries == 1
        assert got[req.request_id] == ref

    def test_tear_between_layers_parks_then_retry_parked_replays(self, dense_setup):
        cfg, model, params = dense_setup
        tokens = self._tokens(cfg, seed=3)
        ref = monolithic_generate(model, params, tokens, 3)
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, consume="layerwise")
        req = svc.submit(tokens)
        victim = req.prefill_worker
        svc.admit_queued()
        fut = svc.decode.inflight[req.request_id].future
        fut.add_layer_callback(
            lambda f, layer: layer == 1 and svc.fail_prefill_worker(victim))
        got = svc.generate_many([req], max_new=3)
        assert got == {}  # no capacity to re-route: parked, not decoded
        assert fut.failed and isinstance(fut.exception(), ConnectionTornError)
        assert fut.exception().request_ids == (req.request_id,)
        assert req.state is RequestState.FAILED
        assert req.request_id not in svc.decode.inflight  # blocks freed
        svc.add_prefill_worker(num_blocks=64)
        assert svc.retry_parked() == [req.request_id]
        assert svc.generate_many([req], max_new=3)[req.request_id] == ref

    def test_survivors_unaffected_by_cobatched_tear(self, dense_setup):
        """Two admissions stream into the same first step; one's source
        dies between layers — the survivor's tokens must be identical to
        a fault-free run (the step restarts without the torn request)."""
        cfg, model, params = dense_setup
        t_victim, t_survivor = self._tokens(cfg, 4), self._tokens(cfg, 5)
        ref_victim = monolithic_generate(model, params, t_victim, 3)
        ref_survivor = monolithic_generate(model, params, t_survivor, 3)
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64, consume="layerwise")
        # route each request to a different prefill worker (least_loaded
        # spreads them), so one teardown hits exactly one pull
        r_victim = svc.submit(t_victim)
        r_survivor = svc.submit(t_survivor)
        assert r_victim.prefill_worker != r_survivor.prefill_worker
        victim_w = r_victim.prefill_worker
        svc.admit_queued()
        fut = svc.decode.inflight[r_victim.request_id].future
        fut.add_layer_callback(
            lambda f, layer: layer == 1 and svc.fail_prefill_worker(victim_w))
        got = svc.generate_many([r_victim, r_survivor], max_new=3)
        assert got[r_survivor.request_id] == ref_survivor
        assert r_survivor.retries == 0
        # the torn request re-prefilled on the survivor worker and still
        # produced the right tokens
        assert got[r_victim.request_id] == ref_victim
        assert r_victim.retries == 1

    def test_worker_level_retry_loop_drops_only_torn(self, dense_setup):
        """DecodeWorker._streaming_step: a ConnectionTornError between
        layers aborts the torn admission (freeing its blocks) and the
        retried step still decodes the survivors."""
        cfg, model, params = dense_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64, consume="layerwise")
        r1 = svc.submit(self._tokens(cfg, 6))
        r2 = svc.submit(self._tokens(cfg, 7))
        assert r1.prefill_worker != r2.prefill_worker  # tear hits only r1
        svc.admit_queued()
        dw = svc.decode
        free_before = dw.pool.num_free
        r1_blocks = len(r1.decode_blocks)
        fut = dw.inflight[r1.request_id].future
        fut.add_layer_callback(
            lambda f, layer: layer == 1
            and svc.engine.deregister_memory(r1.prefill_worker))
        out = dw.decode_round(2, pump_budget=4)
        assert r2.request_id in out and len(out[r2.request_id]) == 2
        assert r1.request_id not in out
        assert r1.request_id not in dw.inflight  # aborted...
        assert dw.pool.num_free == free_before + r1_blocks  # ...blocks freed

    def test_bad_consume_value_rejected(self, dense_setup):
        cfg, model, params = dense_setup
        with pytest.raises(ValueError, match="consume"):
            DisaggService(model, params, consume="eager")
        from repro.core.connection import ChipInfo, WorkerInfo
        info = WorkerInfo("dX", "decode", "host", (ChipInfo(0, "ici://dX/0"),))
        with pytest.raises(ValueError, match="consume"):
            DecodeWorker(info, model, params, consume="eager")
