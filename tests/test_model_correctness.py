"""Numerical correctness of the model-zoo building blocks.

The key invariants:
  * flash (blockwise) attention == dense attention, values AND grads;
  * chunked SSD prefill == token-by-token SSD recurrence;
  * prefill + decode_step(t) == prefill(prompt + t) — the end-to-end
    consistency that serving correctness rests on;
  * MoE never routes to padding experts.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.attention import KVPages, gqa_attention, paged_decode_attention
from repro.models.flash import flash_attention, pair_schedule
from repro.models.moe import moe_apply, moe_init
from repro.models.registry import build_model
from repro.models.ssm import ssm_init, ssm_prefill, ssm_step

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


class TestFlashVsDense:
    @pytest.mark.parametrize("s,h,g,d", [(256, 4, 2, 32), (512, 8, 8, 16), (256, 6, 1, 64)])
    def test_causal_matches(self, s, h, g, d):
        rng = np.random.default_rng(0)
        q, k, v = rand(rng, 2, s, h, d), rand(rng, 2, s, g, d), rand(rng, 2, s, g, d)
        ref = gqa_attention(q, k, v, causal=True)
        out = flash_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def test_sliding_window_with_prefix(self):
        rng = np.random.default_rng(1)
        s, h, g, d, w, m = 256, 4, 2, 32, 64, 16
        q, k, v = rand(rng, 2, s, h, d), rand(rng, 2, s, g, d), rand(rng, 2, s, g, d)
        ref = gqa_attention(q, k, v, causal=True, sliding_window=w, prefix_len=m)
        out = flash_attention(q, k, v, causal=True, sliding_window=w, prefix_len=m,
                              q_chunk=32, k_chunk=32)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def test_gradients_match(self):
        rng = np.random.default_rng(2)
        s, h, g, d = 128, 4, 2, 16
        q, k, v = rand(rng, 1, s, h, d), rand(rng, 1, s, g, d), rand(rng, 1, s, g, d)

        def loss_dense(q, k, v):
            return (gqa_attention(q, k, v, causal=True) ** 2).sum()

        def loss_flash(q, k, v):
            return (flash_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32) ** 2).sum()

        g_ref = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
        g_out = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g_out, g_ref):
            np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)

    def test_schedule_skips_invisible_blocks(self):
        # causal 8x8 chunks: triangular = 36 pairs, not 64
        pi, pj = pair_schedule(512, 512, 64, 64, causal=True)
        assert len(pi) == 36
        # sliding window 64 with no prefix: banded — diag + one off-diag
        pi, pj = pair_schedule(512, 512, 64, 64, causal=True, window=64)
        assert len(pi) == 8 + 7
        # prefix keeps column 0 alive for every row
        pi, pj = pair_schedule(512, 512, 64, 64, causal=True, window=64, prefix=16)
        assert len(pi) == 8 + 7 + 6  # + block-0 column for rows 2..7

    def test_flash_exact_flops_vs_masked_waste(self):
        # The triangular schedule runs (nq(nq+1)/2) / nq² of full compute.
        pi, _ = pair_schedule(4096, 4096, 512, 512, causal=True)
        assert len(pi) == 36  # vs 64 for scan-all-and-mask: 44% saved


class TestSSD:
    def test_chunked_equals_stepwise(self):
        cfg = get_smoke_config("mamba2-780m")
        p = ssm_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(3)
        b, s = 2, 64
        x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)

        y_chunk, (state_chunk, conv_chunk) = ssm_prefill(p, x, cfg, chunk=16)

        # token-by-token
        ssd = jnp.zeros((b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        conv = jnp.zeros((b, cfg.ssm_conv - 1, cfg.ssm_inner + 2 * cfg.ssm_state), jnp.float32)
        ys = []
        st = (ssd, conv)
        for t in range(s):
            y_t, st = ssm_step(p, x[:, t], cfg, st)
            ys.append(y_t)
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(y_chunk, y_seq, rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(state_chunk, st[0], rtol=2e-2, atol=2e-2)

    def test_state_continuation(self):
        """prefill(x) == prefill(x1) then prefill(x2 | state) — the
        correctness base for chunked-prefill and state transfer."""
        cfg = get_smoke_config("mamba2-780m")
        p = ssm_init(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((1, 64, cfg.d_model)), jnp.float32)
        y_full, (s_full, _) = ssm_prefill(p, x, cfg, chunk=16)
        y1, (s1, c1) = ssm_prefill(p, x[:, :32], cfg, chunk=16)
        y2, (s2, _) = ssm_prefill(p, x[:, 32:], cfg, chunk=16, conv_state=c1, ssd_state=s1)
        np.testing.assert_allclose(y_full[:, 32:], y2, rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(s_full, s2, rtol=2e-2, atol=2e-2)


class TestPagedDecode:
    def test_matches_dense_attention(self):
        rng = np.random.default_rng(5)
        b, t, h, g, d, bs = 3, 96, 4, 2, 16, 32
        ctx = jnp.asarray([96, 64, 33], jnp.int32)
        q = rand(rng, b, h, d)
        k_full = rand(rng, b, t, g, d)
        v_full = rand(rng, b, t, g, d)
        # dense reference with per-seq lengths
        ref = gqa_attention(q[:, None], k_full, v_full, causal=True,
                            q_offset=ctx - 1, kv_len=ctx)[:, 0]
        # paged: 3 per-sequence pages each
        per = t // bs
        k_pages = k_full.reshape(b, per, bs, g, d)
        v_pages = v_full.reshape(b, per, bs, g, d)
        tables = jnp.broadcast_to(jnp.arange(per, dtype=jnp.int32)[None, :], (b, per))
        out = paged_decode_attention(q, KVPages(k_pages, v_pages), tables, ctx)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestMoE:
    def test_padding_experts_never_selected(self):
        cfg = get_smoke_config("granite-moe-3b-a800m")  # 5 experts -> padded 16
        p = moe_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.bfloat16)
        # peek at routing
        logits = x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
        logits = jnp.where(jnp.arange(cfg.padded_experts) < cfg.num_experts, logits, -jnp.inf)
        _, idx = jax.lax.top_k(jax.nn.softmax(logits), cfg.experts_per_token)
        assert int(idx.max()) < cfg.num_experts
        out, aux = moe_apply(p, x, cfg)
        assert out.shape == x.shape
        assert jnp.isfinite(aux)

    def test_identical_tokens_get_identical_outputs(self):
        cfg = get_smoke_config("granite-moe-3b-a800m")
        p = moe_init(jax.random.PRNGKey(1), cfg)
        x1 = jnp.ones((1, 8, cfg.d_model), jnp.float32) * 0.3
        out, _ = moe_apply(p, x1, cfg)
        # capacity may drop some duplicates; the kept ones agree
        kept = jnp.abs(out).sum(-1) > 0
        vals = out[kept]
        if vals.shape[0] > 1:
            np.testing.assert_allclose(vals[0], vals[1], rtol=1e-3, atol=1e-3)


class TestPrefillDecodeConsistency:
    """prefill(prompt).decode(t) must equal prefill(prompt+t): the whole
    disaggregated serving path hinges on this equivalence."""

    @pytest.mark.parametrize("arch", ["deepseek-67b", "mamba2-780m", "hymba-1.5b"])
    def test_teacher_forcing_equivalence(self, arch):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        b, s = 2, 64
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)

        # ground truth: full prefill over s+1 tokens
        ref_logits, _ = model.prefill(params, {"tokens": toks}, remat=False)
        # serving path: prefill s, decode token s
        _, state = model.prefill(params, {"tokens": toks[:, :s]}, remat=False)
        out_logits, _ = model.decode_step(params, state, toks[:, s])
        np.testing.assert_allclose(
            out_logits.astype(jnp.float32), ref_logits.astype(jnp.float32),
            rtol=3e-2, atol=3e-2,
        )
