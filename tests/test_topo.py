"""Topology subsystem tests (docs/topology.md).

Covers the cluster model (validated spec, seeded generator, JSON
round-trip), the placement planner's invariants (hypothesis: exactly one
role per machine, >=1 per role, never below the same-seed random
baseline, deterministic), the binding math the sim and router consume,
network-aware routing under ASYMMETRIC per-pair costs (directed links:
the cheap direction wins), the flat ``link_scales`` back-compat contract
(validation + symmetric fallback + degenerate-topology equivalence), and
the real-service topology wiring (``from_cluster_spec``, topology-aware
hot-add, ``NoSpareMachine``, the autoscaler's no-spare metric).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.cluster import ClusterScheduler
from repro.core.connection import ChipInfo, WorkerInfo
from repro.sched import LoadReport, RequestRouter, RouteRequest
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import fixed_requests
from repro.topo import (
    PRESETS,
    PROFILES,
    ClusterGenerator,
    ClusterSpec,
    Link,
    MachineProfile,
    MachineSpec,
    NoSpareMachine,
    Placement,
    PlacementPlanner,
    TopologyBinding,
    WorkloadShape,
    generate_cluster,
    random_placement,
)


@pytest.fixture(scope="module")
def cost():
    from repro.configs import get_config

    return CostModel(get_config("mistral-large-123b"), H100_NODE)


def h100_spec(n: int, links=()) -> ClusterSpec:
    """Homogeneous reference-node cluster (the degenerate topology)."""
    return ClusterSpec(
        name=f"flat{n}",
        machines=tuple(MachineSpec(f"m{i}", PROFILES["8xh100"])
                       for i in range(n)),
        links=tuple(links))


# ----------------------------------------------------------- spec + gen
class TestSpec:
    def test_duplicate_machine_ids_rejected(self):
        with pytest.raises(ValueError, match="duplicate machine ids"):
            ClusterSpec("bad", machines=(
                MachineSpec("m0", PROFILES["8xh100"]),
                MachineSpec("m0", PROFILES["8xa100"])))

    def test_unknown_link_endpoint_rejected(self):
        with pytest.raises(ValueError, match="unknown machine"):
            ClusterSpec("bad",
                        machines=(MachineSpec("m0", PROFILES["8xh100"]),
                                  MachineSpec("m1", PROFILES["8xh100"])),
                        links=(Link("m0", "mX", bandwidth_Bps=1e9),))

    def test_duplicate_link_rejected(self):
        with pytest.raises(ValueError, match="duplicate link"):
            h100_spec(2, links=(Link("m0", "m1", bandwidth_Bps=1e9),
                                Link("m0", "m1", bandwidth_Bps=2e9)))

    def test_link_validation(self):
        with pytest.raises(ValueError, match="self-link"):
            Link("m0", "m0", bandwidth_Bps=1e9)
        with pytest.raises(ValueError, match="non-positive bandwidth"):
            Link("m0", "m1", bandwidth_Bps=0.0)
        with pytest.raises(ValueError, match="negative latency"):
            Link("m0", "m1", bandwidth_Bps=1e9, latency_s=-1e-3)
        with pytest.raises(ValueError, match="unknown tier"):
            Link("m0", "m1", bandwidth_Bps=1e9, tier="wan")
        with pytest.raises(ValueError, match="empty cluster"):
            ClusterSpec("bad", machines=())

    def test_unlisted_pair_defaults_to_nic_limited_rack_link(self):
        spec = ClusterSpec("t", machines=(
            MachineSpec("m0", PROFILES["8xh100"]),   # 400G NIC
            MachineSpec("m1", PROFILES["8xl4"])))    # 100G NIC
        lk = spec.link("m0", "m1")
        assert lk.bandwidth_Bps == PROFILES["8xl4"].nic_Bps
        assert lk.tier == "rack" and lk.latency_s == 0.0

    def test_json_round_trip_is_stable(self):
        spec = generate_cluster("geo_pair", 3)
        wire = spec.to_json()
        again = ClusterSpec.from_json(wire)
        assert again.to_json() == wire
        assert again.ids() == spec.ids()
        assert again.link("m0", "m1") == spec.link("m0", "m1")

    def test_generator_deterministic_per_seed(self):
        for preset in PRESETS:
            a = generate_cluster(preset, 5).to_json()
            b = generate_cluster(preset, 5).to_json()
            assert a == b, f"{preset}: same seed produced different specs"
        assert generate_cluster("hetero_rack", 0).to_json() != \
            generate_cluster("hetero_rack", 1).to_json()

    def test_generator_asymmetric_directions(self):
        spec = generate_cluster("geo_pair", 0)
        ids = spec.ids()
        assert any(
            spec.link(a, b).bandwidth_Bps != spec.link(b, a).bandwidth_Bps
            for a in ids for b in ids if a != b), \
            "asymmetric generator produced a fully symmetric cluster"
        sym = dataclasses.replace(PRESETS["geo_pair"], asymmetric=False)
        spec = sym.generate(0)
        for a in spec.ids():
            for b in spec.ids():
                if a != b:
                    assert spec.link(a, b).bandwidth_Bps == \
                        spec.link(b, a).bandwidth_Bps

    def test_cross_region_links_slower_and_laggier(self):
        gen = PRESETS["geo_pair"]
        spec = gen.generate(2)
        for lk in spec.links:
            src = spec.machine(lk.src)
            dst = spec.machine(lk.dst)
            if src.region == dst.region:
                assert lk.tier == "rack"
                assert lk.latency_s <= gen.intra_latency_s[1]
            else:
                assert lk.tier == "cross_region"
                assert lk.latency_s >= gen.cross_latency_s[0]
                assert lk.bandwidth_Bps <= gen.cross_bw_gbps[1] * 1e9 / 8


# -------------------------------------------------------------- planner
class TestPlanner:
    def test_plan_partitions_every_machine(self):
        spec = generate_cluster("hetero_rack", 0)
        p = PlacementPlanner().plan(spec)
        assert sorted(p.prefill + p.decode) == sorted(spec.ids())
        assert not (set(p.prefill) & set(p.decode))
        assert p.prefill and p.decode

    def test_plan_deterministic(self):
        spec = generate_cluster("geo_triad", 4)
        planner = PlacementPlanner()
        assert planner.plan(spec, seed=3) == planner.plan(spec, seed=3)

    def test_pinned_counts_respected(self):
        spec = generate_cluster("geo_pair", 0)
        p = PlacementPlanner().plan(spec, n_prefill=2, n_decode=3)
        assert len(p.prefill) == 2 and len(p.decode) == 3
        with pytest.raises(ValueError, match="cannot place"):
            PlacementPlanner().plan(spec, n_prefill=8, n_decode=8)

    def test_plan_never_below_random_baseline(self):
        planner = PlacementPlanner()
        for preset in PRESETS:
            spec = generate_cluster(preset, 1)
            planned = planner.plan(spec)
            for seed in range(5):
                rand = random_placement(spec, seed=seed, planner=planner)
                assert planned.score >= rand.score - 1e-9, \
                    f"{preset}: random seed {seed} beat the planner"

    def test_score_charges_the_cross_partition_link(self, cost):
        """A fast prefill machine with only a slow path to decode must
        score below the same machines joined by a fast path."""
        planner = PlacementPlanner(shape=WorkloadShape.from_cost(cost))
        fast = h100_spec(2, links=(Link("m0", "m1", bandwidth_Bps=50e9),))
        slow = h100_spec(2, links=(Link("m0", "m1", bandwidth_Bps=1e9),))
        s_fast = planner.score(fast, ["m0"], ["m1"])
        s_slow = planner.score(slow, ["m0"], ["m1"])
        assert s_slow < s_fast

    def test_placement_validation(self):
        with pytest.raises(ValueError, match=">=1 prefill"):
            Placement(prefill=(), decode=("m0",))
        with pytest.raises(ValueError, match="both roles"):
            Placement(prefill=("m0",), decode=("m0",))


# -------------------------------------------------------------- binding
class TestBinding:
    def test_wid_mapping_positional_over_sorted_ids(self):
        spec = h100_spec(4)
        b = TopologyBinding(spec, Placement(prefill=("m2", "m0"),
                                            decode=("m3", "m1")))
        # Placement sorts: prefill=(m0, m2) -> p0, p1; decode=(m1, m3)
        assert b.machine("p0").machine_id == "m0"
        assert b.machine("p1").machine_id == "m2"
        assert b.machine("d0").machine_id == "m1"
        assert b.machine("d1").machine_id == "m3"
        assert b.machine("d9") is None
        assert b.spares == ()

    def test_scales_are_capability_ratios(self):
        spec = ClusterSpec("t", machines=(
            MachineSpec("m0", PROFILES["8xh100"]),
            MachineSpec("m1", PROFILES["8xa100"])))
        b = TopologyBinding(spec, Placement(prefill=("m0",), decode=("m1",)))
        a100 = PROFILES["8xa100"]
        h100 = PROFILES["8xh100"]
        assert b.prefill_slowdown("p0", h100.peak_flops) == 1.0
        assert b.decode_slowdown("d0", h100.hbm_Bps) == \
            h100.hbm_Bps / a100.hbm_Bps
        assert b.cap_scale("d0", h100.vram_bytes) == \
            a100.vram_bytes / h100.vram_bytes
        # pair cost: the directed prefill->decode link, NIC-limited
        assert b.pair_scale("p0", "d0", 50e9) == 50e9 / a100.nic_Bps
        assert b.pair_latency_s("p0", "d0") == 0.0

    def test_spare_lifecycle_and_no_spare(self):
        spec = h100_spec(3)
        b = TopologyBinding(spec, Placement(prefill=("m0",), decode=("m1",)))
        assert b.spares == ("m2",)
        assert b.has_spare("prefill")
        m = b.add_worker("prefill", "p1")
        assert m.machine_id == "m2" and b.spares == ()
        with pytest.raises(NoSpareMachine):
            b.add_worker("decode", "d1")
        with pytest.raises(ValueError, match="already bound"):
            b.add_worker("prefill", "p1")
        b.release_worker("p1")
        assert b.spares == ("m2",)

    def test_pick_spare_maximizes_planner_score(self, cost):
        """With a planner attached, a hot-add claims the spare whose
        addition maximizes max-flow — not just the beefiest machine."""
        # m2 (H100) has only a starved link to the decode machine; m3
        # (slower A100) has a fat one.  A decode-side... prefill add
        # must prefer m3 despite m2's higher FLOPs.
        spec = ClusterSpec("t", machines=(
            MachineSpec("m0", PROFILES["8xa100"]),
            MachineSpec("m1", PROFILES["8xh100"]),
            MachineSpec("m2", PROFILES["8xh100"]),
            MachineSpec("m3", PROFILES["8xa100"])),
            links=(Link("m2", "m1", bandwidth_Bps=0.1e9),
                   Link("m3", "m1", bandwidth_Bps=25e9)))
        planner = PlacementPlanner(shape=WorkloadShape.from_cost(cost))
        b = TopologyBinding(spec, Placement(prefill=("m0",), decode=("m1",)),
                            planner=planner)
        assert b.pick_spare("prefill") == "m3"


# -------------------------------------------- asymmetric-cost routing
def _asym_spec() -> ClusterSpec:
    """m0 prefill; m1/m2 decode.  FORWARD m0->m1 is fast and m0->m2 is
    starved; the REVERSE directions are deliberately opposite, so a
    router that priced the wrong direction would flip its pick."""
    return h100_spec(3, links=(
        Link("m0", "m1", bandwidth_Bps=50e9),        # cheap forward
        Link("m1", "m0", bandwidth_Bps=0.5e9),       # expensive reverse
        Link("m0", "m2", bandwidth_Bps=0.5e9),       # expensive forward
        Link("m2", "m0", bandwidth_Bps=50e9)))       # cheap reverse


def _router(links) -> RequestRouter:
    cs = ClusterScheduler()
    for wid, role in (("p0", "prefill"), ("d0", "decode"), ("d1", "decode")):
        cs.add_worker(WorkerInfo(wid, role, f"host-{wid}",
                                 (ChipInfo(0, f"ici://{wid}/0"),)))
        cs.heartbeat(wid, 0.0, load=LoadReport(wid, role, 64, 64))
    return RequestRouter(cs, "network_aware", links=links)


class TestAsymmetricRouting:
    def test_router_prices_the_forward_direction(self):
        b = TopologyBinding(_asym_spec(),
                            Placement(prefill=("m0",), decode=("m1", "m2")))
        r = _router(b.links())
        d = r.route(RouteRequest("r0", 4096, kv_bytes=64 << 20))
        assert d.decode_worker == "d0", \
            "network_aware did not pick the cheap m0->m1 direction"

    def test_router_charges_link_latency(self):
        """Equal bandwidth, one path with cross-region latency: the
        low-latency pair must win (latency_s flows through
        modeled_transfer_s once per pull)."""
        spec = h100_spec(3, links=(
            Link("m0", "m1", bandwidth_Bps=25e9, latency_s=0.0),
            Link("m0", "m2", bandwidth_Bps=25e9, latency_s=30e-3,
                 tier="cross_region")))
        b = TopologyBinding(spec,
                            Placement(prefill=("m0",), decode=("m1", "m2")))
        r = _router(b.links())
        # tiny KV: wire time ~0, so the 30 ms propagation dominates
        d = r.route(RouteRequest("r0", 128, kv_bytes=1 << 16))
        assert d.decode_worker == "d0"

    def test_sim_routes_down_the_cheap_direction(self, cost):
        b = TopologyBinding(_asym_spec(),
                            Placement(prefill=("m0",), decode=("m1", "m2")))
        sim = ClusterSim(cost, SimConfig(mode="pull", n_prefill=1,
                                         n_decode=2, policy="network_aware"),
                         topology=b)
        reqs = fixed_requests(16384, 32, qps=0.2, duration_s=40, seed=3)
        res = sim.run(list(reqs))
        assert res.requests and all(
            r.decode_worker == "d0" for r in res.requests), \
            "sim's network_aware routing ignored the directed pair costs"


# -------------------------------------------- link_scales back-compat
class TestLinkScales:
    def test_flat_config_unchanged(self, cost):
        """Regression: the pre-topology flat form still works as-is."""
        reqs = fixed_requests(16384, 32, qps=0.3, duration_s=40, seed=4)
        sim = ClusterSim(cost, SimConfig(mode="pull", n_prefill=1, n_decode=2,
                                         policy="network_aware"),
                         link_scales={("p0", "d1"): 5.0})
        res = sim.run(list(reqs))
        assert len(res.requests) == len(reqs)

    def test_reversed_pair_rejected_without_symmetric(self, cost):
        with pytest.raises(ValueError, match="keys are directed"):
            ClusterSim(cost, SimConfig(n_prefill=1, n_decode=2),
                       link_scales={("d1", "p0"): 5.0})

    def test_unknown_worker_rejected(self, cost):
        with pytest.raises(ValueError, match="unknown"):
            ClusterSim(cost, SimConfig(n_prefill=1, n_decode=2),
                       link_scales={("p0", "d7"): 5.0})

    def test_symmetric_fallback_normalizes_reversed_keys(self, cost):
        """(d, p) keys under symmetric_links=True behave exactly like
        the (p, d) form — same sim, same numbers."""
        reqs = fixed_requests(16384, 32, qps=0.3, duration_s=40, seed=4)
        runs = {}
        for name, kw in {
            "forward": dict(link_scales={("p0", "d1"): 5.0}),
            "reversed": dict(link_scales={("d1", "p0"): 5.0},
                             symmetric_links=True),
        }.items():
            sim = ClusterSim(cost, SimConfig(mode="pull", n_prefill=1,
                                             n_decode=2), **kw)
            assert sim.link_scales == {("p0", "d1"): 5.0}
            runs[name] = sim.run(list(reqs)).summary()
        assert runs["forward"] == runs["reversed"]

    def test_conflicting_symmetric_values_rejected(self, cost):
        with pytest.raises(ValueError, match="conflict"):
            ClusterSim(cost, SimConfig(n_prefill=1, n_decode=2),
                       link_scales={("p0", "d1"): 5.0, ("d1", "p0"): 2.0},
                       symmetric_links=True)

    def test_topology_excludes_flat_knobs(self, cost):
        b = TopologyBinding(h100_spec(2),
                            Placement(prefill=("m0",), decode=("m1",)))
        cfg = SimConfig(mode="pull", n_prefill=1, n_decode=1)
        with pytest.raises(ValueError, match="mutually exclusive"):
            ClusterSim(cost, cfg, topology=b,
                       link_scales={("p0", "d0"): 2.0})
        with pytest.raises(ValueError, match="mutually exclusive"):
            ClusterSim(cost, cfg, topology=b,
                       prefill_slowdowns={"p0": 2.0})
        with pytest.raises(ValueError, match="binds 1P\\+1D"):
            ClusterSim(cost, SimConfig(mode="pull", n_prefill=2, n_decode=1),
                       topology=b)

    def test_degenerate_topology_matches_flat_sim(self, cost):
        """A homogeneous reference-node ClusterSpec (default NIC-limited
        links = the reference 400G link) must reproduce the flat sim
        EXACTLY — scales all 1.0, latency 0."""
        reqs = fixed_requests(16384, 64, qps=0.5, duration_s=60, seed=6)
        cfg = SimConfig(mode="pull", n_prefill=2, n_decode=2,
                        policy="network_aware")
        flat = ClusterSim(cost, cfg).run(list(reqs)).summary()
        b = TopologyBinding(h100_spec(4),
                            Placement(prefill=("m0", "m1"),
                                      decode=("m2", "m3")))
        topo = ClusterSim(cost, cfg, topology=b).run(list(reqs)).summary()
        for k, v in flat.items():
            assert topo[k] == v or (math.isnan(v) and math.isnan(topo[k])), \
                f"degenerate topology drifted from flat sim on {k}"


# ------------------------------------------------------- real substrate
class TestServiceTopology:
    @pytest.fixture(scope="class")
    def smoke(self):
        import jax

        from repro.configs import get_smoke_config
        from repro.models.transformer import DecoderLM

        cfg = get_smoke_config("deepseek-67b")
        model = DecoderLM(cfg, unroll=True)
        return cfg, model, model.init_params(jax.random.PRNGKey(0))

    def test_from_cluster_spec_binds_and_serves(self, smoke):
        from repro.serving.disagg import DisaggService

        cfg, model, params = smoke
        spec = generate_cluster("hetero_rack", 0)
        svc = DisaggService.from_cluster_spec(model, params, spec,
                                              num_blocks=32)
        b = svc.topology
        planned = PlacementPlanner().plan(spec)
        assert (b.placement.prefill, b.placement.decode) == \
            (planned.prefill, planned.decode)
        assert len(svc.prefills) == len(planned.prefill)
        assert len(svc.decodes) == len(planned.decode)
        # every (prefill, decode) pair is priced from the spec's links
        assert set(svc.router.links) == {
            (p, d) for p in svc.prefills for d in svc.decodes}
        for (p, d), lm in svc.router.links.items():
            lk = b.pair_link(p, d)
            assert lm.bandwidth_Bps == lk.bandwidth_Bps
            assert lm.latency_s == lk.latency_s
        prompt = np.arange(40, dtype=np.int32) % cfg.vocab_size
        out = svc.generate(svc.submit(prompt), max_new=4)
        assert len(out) >= 4

    def test_vram_scales_worker_pools(self, smoke):
        from repro.serving.disagg import DisaggService

        cfg, model, params = smoke
        spec = ClusterSpec("t", machines=(
            MachineSpec("m0", PROFILES["8xh100"]),
            MachineSpec("m1", PROFILES["8xh100"]),
            MachineSpec("m2", PROFILES["8xl4"])))
        svc = DisaggService.from_cluster_spec(
            model, params, spec,
            placement=Placement(prefill=("m0",), decode=("m1", "m2")),
            num_blocks=40)
        pools = {w: svc.decodes[w].pool.stats.capacity for w in svc.decodes}
        ratio = PROFILES["8xl4"].vram_bytes / PROFILES["8xh100"].vram_bytes
        assert pools["d0"] == 40                      # m1: reference VRAM
        assert pools["d1"] == max(1, round(40 * ratio))  # m2: 0.3x VRAM

    def test_hot_add_consumes_spares_then_raises(self, smoke):
        from repro.serving.disagg import DisaggService

        cfg, model, params = smoke
        spec = h100_spec(3)
        svc = DisaggService.from_cluster_spec(
            model, params, spec,
            placement=Placement(prefill=("m0",), decode=("m1",)),
            num_blocks=16)
        assert svc.topology.spares == ("m2",)
        wid = svc.add_prefill_worker(num_blocks=16)
        assert svc.topology.machine(wid).machine_id == "m2"
        # hot-add refreshed the router's pair map for the new worker
        assert (wid, "d0") in svc.router.links
        with pytest.raises(NoSpareMachine):
            svc.add_decode_worker(num_blocks=16)

    def test_autoscaler_skips_add_when_no_spare(self, smoke):
        from repro.fleet import FleetConfig
        from repro.serving.disagg import DisaggService

        cfg, model, params = smoke
        spec = h100_spec(2)
        svc = DisaggService.from_cluster_spec(
            model, params, spec, num_blocks=16,
            fleet=FleetConfig(autoscale=True))
        assert svc.topology.spares == ()
        assert svc.fleet._add("prefill") is None
        assert svc.metrics.counters()["fleet.autoscale_no_spare"] == 1
        assert len(svc.prefills) == 1  # nothing was conjured
