"""§4.1 descriptor arithmetic — including the paper's worked example."""
import pytest

from repro.core.descriptors import ByteRange, ReadTxn, TensorDesc, build_block_reads


def paper_desc(worker="prefill0"):
    # Figure 5 of the paper, verbatim.
    return TensorDesc(
        address=0x7F06F40000,
        dims=("B", "KV", "L", "H", "D"),
        shape=(10, 2, 16, 2, 128),
        stride=(4096, 40960, 256, 128, 1),
        itemsize=2,
        worker_id=worker,
        tensor_id="layer0/kv",
    )


class TestPaperWorkedExample:
    def test_block8_k_offset(self):
        d = paper_desc()
        assert d.byte_offset((8, 0, 0, 0, 0)) == 65536

    def test_block8_v_offset(self):
        # The paper prints 147453 B; (8*4096 + 40960) * 2 = 147456 B.
        d = paper_desc()
        assert d.byte_offset((8, 1, 0, 0, 0)) == 147456

    def test_contiguous_span_covers_LHD(self):
        d = paper_desc()
        assert d.contiguous_span(("L", "H", "D")) == 8192  # 16*2*128*2B

    def test_block_ranges_two_disjoint_8192B_spans(self):
        # Ranges are absolute: base address + relative offset.
        d = paper_desc()
        rs = d.block_ranges(8)
        assert [r.nbytes for r in rs] == [8192, 8192]
        assert rs[0].offset == d.address + 65536
        assert rs[1].offset == d.address + 147456

    def test_adjacent_blocks_abut(self):
        # Blocks 0 and 1: K offsets 0 and 8192 — coalescable (paper: one
        # 16384 B transaction).
        d = paper_desc()
        k0, k1 = d.block_ranges(0)[0], d.block_ranges(1)[0]
        assert k0.abuts(k1)
        assert k0.merged(k1).nbytes == 16384


class TestTensorDescValidation:
    def test_rank_mismatch(self):
        with pytest.raises(ValueError):
            TensorDesc(0, ("A", "B"), (2,), (1,), 2)

    def test_duplicate_dims(self):
        with pytest.raises(ValueError):
            TensorDesc(0, ("B", "B"), (2, 2), (2, 1), 2)

    def test_out_of_range_index(self):
        with pytest.raises(IndexError):
            paper_desc().element_offset((10, 0, 0, 0, 0))

    def test_non_dense_span_rejected(self):
        # Pad H's stride: L/H/D no longer densely packed.
        d = TensorDesc(0, ("B", "KV", "L", "H", "D"), (10, 2, 16, 2, 128),
                       (5120, 51200, 320, 160, 1), 2)
        with pytest.raises(ValueError, match="densely packed"):
            d.contiguous_span(("L", "H", "D"))

    def test_total_nbytes(self):
        assert paper_desc().nbytes == 10 * 2 * 16 * 2 * 128 * 2


class TestBuildBlockReads:
    def test_translates_block_pairs(self):
        remote = paper_desc("prefill0")
        local = TensorDesc(
            address=0x1000,
            dims=("B", "KV", "L", "H", "D"),
            shape=(10, 2, 16, 2, 128),
            stride=(4096, 40960, 256, 128, 1),
            itemsize=2,
            worker_id="decode0",
            tensor_id="layer0/kv",
        )
        txns = list(build_block_reads("r1", remote, local, [8, 0], [3, 4]))
        assert len(txns) == 4  # 2 blocks x (K, V)
        assert all(isinstance(t, ReadTxn) for t in txns)
        assert txns[0].remote.offset == remote.address + 65536  # remote block 8 K
        assert txns[0].local.offset == 0x1000 + 3 * 8192        # local block 3 K
        assert {t.nbytes for t in txns} == {8192}
        assert all(t.src_worker == "prefill0" and t.dst_worker == "decode0" for t in txns)

    def test_length_mismatch_rejected(self):
        d = paper_desc()
        with pytest.raises(ValueError):
            list(build_block_reads("r", d, d, [0, 1], [0]))

    def test_size_mismatch_rejected(self):
        remote = paper_desc()
        local = TensorDesc(0, ("B", "KV", "L", "H", "D"), (10, 2, 8, 2, 128),
                           (2048, 20480, 256, 128, 1), 2, worker_id="d")
        with pytest.raises(ValueError, match="layout mismatch"):
            list(build_block_reads("r", remote, local, [0], [0]))


class TestByteRange:
    def test_invalid(self):
        with pytest.raises(ValueError):
            ByteRange(-1, 4)
        with pytest.raises(ValueError):
            ByteRange(0, 0)

    def test_merge_requires_adjacency(self):
        with pytest.raises(ValueError):
            ByteRange(0, 4).merged(ByteRange(8, 4))
