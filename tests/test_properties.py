"""Property-based tests (hypothesis) on the system's core invariants.

1. Descriptor arithmetic: for ANY dense paged-KV layout, the byte ranges
   computed for a block must exactly tile the bytes numpy says that block
   occupies — the §4.1 dot-product math can never corrupt a transfer.
2. Coalescing: for ANY transaction window, merged reads move exactly the
   same (remote → local) byte mapping, never overlap, and never reorder
   bytes — with FIFO and sorted strategies.
3. Transfer engine: for ANY program of reads (+ final COMPLETEs), the
   destination buffer equals the oracle scatter/gather result.
"""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.coalesce import coalesce
from repro.core.descriptors import ByteRange, CompleteTxn, ReadTxn, TensorDesc, build_block_reads
from repro.core.transfer_engine import MemoryRegion, TransferEngine

# ---------------------------------------------------------------- layouts
dims_orders = st.permutations(["B", "KV", "L", "H", "D"])


@st.composite
def dense_layouts(draw):
    """A dense 5-D paged-KV tensor with a random dim ORDER in memory."""
    extents = {
        "B": draw(st.integers(2, 12)),
        "KV": 2,
        "L": draw(st.sampled_from([4, 8, 16])),
        "H": draw(st.sampled_from([1, 2, 4])),
        "D": draw(st.sampled_from([8, 16, 32])),
    }
    # Contract (found by hypothesis): the block dim must not be the
    # INNERMOST memory dim — a block would have no contiguous span and
    # every element would need its own transaction.  descriptors.py
    # rejects such layouts explicitly; we generate only valid ones.
    mem_order = draw(dims_orders.filter(lambda o: o[-1] != "B"))
    strides = {}
    span = 1
    for d in reversed(mem_order):
        strides[d] = span
        span *= extents[d]
    logical = ("B", "KV", "L", "H", "D")
    return TensorDesc(
        address=draw(st.sampled_from([0, 0x1000, 0x7F00000000])),
        dims=logical,
        shape=tuple(extents[d] for d in logical),
        stride=tuple(strides[d] for d in logical),
        itemsize=2,
        worker_id="w",
        tensor_id="t",
    ), extents, mem_order


@settings(max_examples=150, deadline=None)
@given(dense_layouts(), st.data())
def test_block_ranges_tile_numpy_truth(layout, data):
    """block_ranges(b) must cover exactly the bytes numpy assigns block b."""
    desc, extents, mem_order = layout
    b = data.draw(st.integers(0, extents["B"] - 1))
    # ground truth via numpy strides
    arr = np.arange(np.prod([extents[d] for d in mem_order]), dtype=np.int64)
    view = arr.reshape([extents[d] for d in mem_order]).transpose(
        [mem_order.index(d) for d in ("B", "KV", "L", "H", "D")])
    truth = set(view[b].reshape(-1).tolist())  # element offsets of block b

    got = set()
    for r in desc.block_ranges(b):
        start = (r.offset - desc.address) // desc.itemsize
        n = r.nbytes // desc.itemsize
        got.update(range(start, start + n))
    assert got == truth


@st.composite
def txn_windows(draw):
    n_pages = draw(st.integers(4, 32))
    page = draw(st.sampled_from([64, 256, 1024]))
    n = draw(st.integers(1, n_pages))
    src_ids = draw(st.permutations(list(range(n_pages))))[:n]
    dst_ids = draw(st.permutations(list(range(n_pages))))[:n]
    txns = [
        ReadTxn(f"r{i}", "p", "d", ByteRange(s * page, page), ByteRange(t * page, page))
        for i, (s, t) in enumerate(zip(src_ids, dst_ids))
    ]
    return txns, page, n_pages


@settings(max_examples=150, deadline=None)
@given(txn_windows(), st.sampled_from(["none", "fifo", "sorted"]))
def test_coalescing_preserves_byte_mapping(window, strategy):
    txns, page, n_pages = window
    merged = coalesce(txns, strategy=strategy)
    # 1. total bytes conserved
    assert sum(m.nbytes for m in merged) == sum(t.nbytes for t in txns)
    # 2. expand merged ops back to (remote_byte → local_byte) pairs
    mapping = {}
    for m in merged:
        for off in range(m.nbytes):
            mapping[m.remote.offset + off] = m.local.offset + off
    truth = {}
    for t in txns:
        for off in range(t.nbytes):
            truth[t.remote.offset + off] = t.local.offset + off
    assert mapping == truth
    # 3. no read overlaps another's local range
    spans = sorted((m.local.offset, m.local.end) for m in merged)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0


@settings(max_examples=60, deadline=None)
@given(txn_windows(), st.sampled_from(["fifo", "sorted"]))
def test_engine_matches_oracle(window, strategy):
    txns, page, n_pages = window
    rng = np.random.default_rng(0)
    src = rng.integers(0, 255, n_pages * page, dtype=np.uint8)
    dst0 = rng.integers(0, 255, n_pages * page, dtype=np.uint8)

    # oracle
    expect = dst0.copy()
    for t in txns:
        expect[t.local.offset : t.local.end] = src[t.remote.offset : t.remote.end]

    eng = TransferEngine(coalescing=strategy)
    dst = dst0.copy()
    # disjoint address spaces: rebase the destination MR and the local
    # ranges by the same constant (the engine rejects overlapping MRs)
    base = n_pages * page
    eng.register_memory(MemoryRegion("p", 0, src))
    eng.register_memory(MemoryRegion("d", base, dst))
    shifted = [
        dataclasses.replace(t, local=ByteRange(t.local.offset + base, t.local.nbytes))
        for t in txns
    ]
    eng.submit(shifted)
    eng.drain()
    np.testing.assert_array_equal(dst, expect)
    assert eng.stats.reads_posted <= len(txns)


@settings(max_examples=100, deadline=None)
@given(dense_layouts(), st.data())
def test_build_block_reads_size_totals(layout, data):
    """A request transfer moves exactly blocks × block_bytes, regardless of
    layout or block permutation."""
    desc, extents, _ = layout
    n = data.draw(st.integers(1, extents["B"]))
    remote = data.draw(st.permutations(list(range(extents["B"]))))[:n]
    local = data.draw(st.permutations(list(range(extents["B"]))))[:n]
    txns = list(build_block_reads("r", desc, desc, remote, local))
    per_block = extents["KV"] * extents["L"] * extents["H"] * extents["D"] * 2
    assert sum(t.nbytes for t in txns) == n * per_block


# --------------------------------------------------------------------
# 4. Async engine scheduling: for ANY interleaving of submit / budgeted
#    progress / poll / drain, byte movement is identical to a one-shot
#    drain, and layer-tagged pulls complete strictly in layer order with
#    monotone (prefix-preserving) ``layers_done`` growth — the invariants
#    the layerwise decode consumer (wait_layer) is built on.
# --------------------------------------------------------------------
_PAGE = 64


@st.composite
def layered_programs(draw):
    """Per-request layer-ordered read programs (the shape ``pull_kv``
    emits: layer 0 first, COMPLETE last) plus a random schedule of
    engine operations."""
    n_layers = draw(st.integers(1, 4))
    n_reqs = draw(st.integers(1, 4))
    programs = []
    page_idx = 0
    for r in range(n_reqs):
        txns = []
        n_blocks = draw(st.integers(1, 3))
        for layer in range(n_layers):
            for _ in range(n_blocks):
                txns.append(ReadTxn(
                    f"r{r}", "p", "d",
                    ByteRange(page_idx * _PAGE, _PAGE),
                    ByteRange(page_idx * _PAGE, _PAGE),
                    layer=layer,
                ))
                page_idx += 1
        txns.append(CompleteTxn(f"r{r}", "p", "d"))
        programs.append(txns)
    # schedule: the submits in order, progress/poll randomly interleaved
    ops = [("submit", i) for i in range(n_reqs)]
    n_extra = draw(st.integers(0, 12))
    for _ in range(n_extra):
        kind = draw(st.sampled_from(["progress", "poll"]))
        budget = draw(st.integers(1, 7)) if kind == "progress" else 0
        pos = draw(st.integers(0, len(ops)))
        ops.insert(pos, (kind, budget))
    return programs, ops, page_idx


def _engine_for(total_pages):
    rng = np.random.default_rng(3)
    src = rng.integers(0, 255, max(total_pages, 1) * _PAGE, dtype=np.uint8)
    dst = np.zeros_like(src)
    eng = TransferEngine(coalescing="fifo")
    base = src.nbytes
    eng.register_memory(MemoryRegion("p", 0, src))
    eng.register_memory(MemoryRegion("d", base, dst))
    return eng, dst, base


def _rebase(txns, base):
    return [
        dataclasses.replace(t, local=ByteRange(t.local.offset + base, t.local.nbytes))
        if isinstance(t, ReadTxn) else t
        for t in txns
    ]


@settings(max_examples=80, deadline=None)
@given(layered_programs())
def test_any_interleaving_matches_one_shot_drain(program):
    programs, ops, total_pages = program
    # reference: submit everything, one drain
    ref, ref_dst, ref_base = _engine_for(total_pages)
    for txns in programs:
        ref.submit(_rebase(txns, ref_base))
    ref.drain()

    eng, dst, base = _engine_for(total_pages)
    futures = {}
    snapshots = {i: [()] for i in range(len(programs))}
    polled = []
    for op, arg in ops:
        if op == "submit":
            (fut,) = eng.submit(_rebase(programs[arg], base))
            futures[arg] = fut
        elif op == "progress":
            eng.progress(arg)
        else:
            polled.extend(f.request_id for f in eng.poll())
        for i, fut in futures.items():
            snapshots[i].append(fut.layers_done)
    eng.drain()
    polled.extend(f.request_id for f in eng.poll())

    # 1. byte-identical to the one-shot drain, same completes
    np.testing.assert_array_equal(dst, ref_dst)
    assert eng.stats.bytes_moved == ref.stats.bytes_moved
    assert eng.stats.completes == ref.stats.completes == len(programs)
    # 2. every future resolved with layers 0..L-1 in strict layer order
    n_layers = max(t.layer for t in programs[0] if isinstance(t, ReadTxn)) + 1
    for i, fut in futures.items():
        assert fut.done() and not fut.failed
        assert fut.layers_done == tuple(range(n_layers))
    # 3. layers_done is MONOTONE: each snapshot extends the previous
    for i, snaps in snapshots.items():
        for a, b in zip(snaps, snaps[1:]):
            assert b[: len(a)] == a
    # 4. every request's completion was observable exactly once via poll
    assert sorted(polled) == sorted(f"r{i}" for i in range(len(programs)))


# ------------------------------------------------- block pool lifecycle
# 4. Delta-transfer lifecycle: for ANY interleaving of retain / evict /
#    delta-admit / torn-pull on one BlockPool, refcounts never double-
#    free, never leak, and free() reports exactly the ids whose last
#    reference dropped (the contract the hash-dedup purge rides on).
pool_ops = st.lists(
    st.tuples(st.sampled_from(["admit", "finish", "torn", "evict"]),
              st.integers(0, 7)),
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(pool_ops, st.integers(4, 24))
def test_pool_delta_lifecycle_never_leaks_or_double_frees(ops, capacity):
    from repro.serving.blocks import BlockPool, OutOfBlocks

    pool = BlockPool(capacity, block_size=4)
    shadow: dict[int, int] = {}  # block -> expected refcount

    def s_free(blocks):
        """Mirror pool.free in the shadow model; return expected releases."""
        released = []
        for b in blocks:
            shadow[b] -= 1
            if shadow[b] == 0:
                del shadow[b]
                released.append(b)
        return released

    live: dict[int, list[int]] = {}  # request -> its block list
    cache: list[list[int]] = []      # retained prefixes, LRU order
    next_rid = 0

    for op, arg in ops:
        if op == "admit":
            # graft the LRU-newest retained prefix (share FIRST, like
            # admit_async), then allocate a suffix; on OutOfBlocks evict
            # retained prefixes, then give up cleanly (un-share the graft)
            graft = list(cache[-1]) if cache else []
            n = len(graft) + arg % 3 + 1
            if graft:
                pool.share(graft)
                for b in graft:
                    shadow[b] += 1
            need = n - len(graft)
            try:
                try:
                    fresh = pool.allocate(need)
                except OutOfBlocks:
                    while cache and not pool.can_allocate(need):
                        ev = cache.pop(0)
                        assert pool.free(ev) == s_free(ev)
                    fresh = pool.allocate(need)
            except OutOfBlocks:
                if graft:
                    assert pool.free(graft) == s_free(graft)
                continue
            for b in fresh:
                assert b not in shadow
                shadow[b] = 1
            live[next_rid] = graft + fresh
            next_rid += 1
        elif op in ("finish", "torn") and live:
            rid = sorted(live)[arg % len(live)]
            blocks = live.pop(rid)
            if op == "finish" and blocks:  # retain a prefix before freeing
                prefix = blocks[: max(1, len(blocks) // 2)]
                pool.share(prefix)
                for b in prefix:
                    shadow[b] += 1
                cache.append(list(prefix))
                while len(cache) > 2:  # bounded cap, evict LRU
                    ev = cache.pop(0)
                    assert pool.free(ev) == s_free(ev)
            # torn: abort frees everything — grafted ids just decrement
            assert pool.free(blocks) == s_free(blocks)
        elif op == "evict" and cache:
            ev = cache.pop(arg % len(cache))
            assert pool.free(ev) == s_free(ev)
        pool.check_invariants()
        assert pool.stats.in_use == len(shadow)

    # drain everything: the pool must return to fully free — no leaks
    for blocks in live.values():
        assert pool.free(blocks) == s_free(blocks)
    for ev in cache:
        assert pool.free(ev) == s_free(ev)
    assert not shadow
    assert pool.num_free == capacity
    pool.check_invariants()
