"""Train a ~100M-param LM for a few hundred steps with checkpoint/restart.

Uses the yi-9b FAMILY at a ~100M reduced width (the full configs are
dry-run-only on CPU); demonstrates loss descent, checkpointing, and
crash-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data.pipeline import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # ~100M params: widen the yi smoke family
    cfg = dataclasses.replace(
        get_smoke_config("yi-9b"),
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=2,
        d_ff=1408, vocab_size=32768,
    )
    model = build_model(cfg)
    print(f"config: {cfg.describe()}")

    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=20, total_steps=args.steps)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt_cfg)
    data = SyntheticLMDataset(cfg.vocab_size, seq_len=128, batch_size=8)
    step_fn = jax.jit(make_train_step(model, opt_cfg, remat=False))

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    t0, first_loss = time.time(), None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        loss = float(m["loss"])
        first_loss = first_loss or loss
        if step % 20 == 0:
            print(f"step {step:4d}  loss {loss:.4f}  ({time.time()-t0:.0f}s)")
        if step == args.steps // 2:
            save_checkpoint(ckpt_dir, step, (params, opt_state, data.state()))
            print(f"--- checkpointed at step {step}; simulating crash+restart ---")
            # crash: rebuild everything from disk
            params = model.init_params(jax.random.PRNGKey(1))  # wrong weights
            opt_state = adamw_init(params, opt_cfg)
            s = latest_step(ckpt_dir)
            params, opt_state, dstate = restore_checkpoint(
                ckpt_dir, s, (params, opt_state, data.state()))
            data.restore(jax.tree.map(int, dstate))
            print(f"--- resumed from step {s} ---")
    print(f"final loss {loss:.4f} (from {first_loss:.4f}) — "
          f"{'DECREASED ✓' if loss < first_loss else 'no descent ✗'}")


if __name__ == "__main__":
    main()
