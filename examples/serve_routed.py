"""Routed disaggregated serving: N prefill × M decode with pluggable
scheduling policies (repro.sched).

Demonstrates, on the REAL pipeline (JAX prefill, one-sided KV pulls):
  * network-aware routing — decode selection follows the modeled
    transfer cost of each request's KV over the (prefill, decode) link;
  * SLO-aware admission — requests whose projected TTFT misses their
    deadline class are rejected up front;
  * failover for BOTH roles — prefill and decode crashes re-route
    in-flight requests.

    PYTHONPATH=src python examples/serve_routed.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.transfer_engine import LinkModel
from repro.models.registry import build_model
from repro.sched import AdmissionRejected
from repro.serving.disagg import DisaggService


def main() -> None:
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    print("== network-aware routing over a skewed 2P x 2D topology ==")
    # rail-aligned links are fast ICI; cross-rail links cross the DCN
    links = {
        ("p0", "d0"): LinkModel.ici(), ("p1", "d1"): LinkModel.ici(),
        ("p0", "d1"): LinkModel.dcn(), ("p1", "d0"): LinkModel.dcn(),
    }
    svc = DisaggService(model, params, n_prefill=2, n_decode=2, num_blocks=128,
                        policy="network_aware", links=links)
    for _ in range(4):
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        req = svc.submit(tokens)
        out = svc.generate(req, max_new=4)
        print(f"  {req.request_id}: prefill@{req.prefill_worker} -> "
              f"decode@{req.decode_worker} tokens {out}")
    s = svc.engine.stats
    print(f"  engine: {s.txns_submitted} txns -> {s.reads_posted} reads "
          f"(coalesce {s.coalesce_factor:.1f}x), {s.bytes_moved/2**20:.1f} MiB; "
          f"router modeled transfer {svc.router.total_transfer_cost_s*1e3:.2f} ms")

    print("== SLO-aware admission: reject what cannot meet its deadline ==")
    slow_prefill = lambda n: n / 100.0  # pretend prefill is ~100 tok/s
    svc2 = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=128,
                         policy="slo", prefill_time_fn=slow_prefill,
                         slo_classes={"interactive": 1.0, "batch": float("inf")})
    for i in range(4):
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        try:
            req = svc2.submit(tokens, slo_class="interactive", now=0.0)
            d = svc2.router.decisions[req.request_id]
            print(f"  {req.request_id}: admitted (projected TTFT "
                  f"{d.projected_ttft_s:.2f}s <= 1.0s)")
        except AdmissionRejected as e:
            print(f"  rejected: {e}")

    print("== failover: decode crash mid-flight, prefill crash mid-flight ==")
    svc3 = DisaggService(model, params, n_prefill=2, n_decode=2, num_blocks=128)
    tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    req = svc3.submit(tokens)
    victim = req.decode_worker
    svc3.fail_decode_worker(victim)
    print(f"  decode {victim} died -> re-routed to {req.decode_worker} "
          f"(retries={req.retries})")
    out = svc3.generate(req, max_new=4)
    print(f"  {req.request_id}: recovered -> tokens {out}")
    tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    req = svc3.submit(tokens)
    victim = req.prefill_worker
    svc3.fail_prefill_worker(victim)
    print(f"  prefill {victim} died -> re-prefilled on {req.prefill_worker} "
          f"(retries={req.retries})")
    out = svc3.generate(req, max_new=4)
    print(f"  {req.request_id}: recovered -> tokens {out}")


if __name__ == "__main__":
    main()
