"""Streaming disaggregated serving: per-request handles over the
event-driven ServeLoop (continuous batching).

Demonstrates, on the REAL pipeline (JAX prefill, one-sided KV pulls):
  * ``submit()`` returns a ``RequestHandle`` immediately; tokens stream
    out as ``ServeLoop.tick()`` interleaves prefill dispatch, router
    admission, transfer progress, and per-step decode;
  * CONTINUOUS batching — a request submitted mid-decode produces its
    first token before the earlier request finishes (no cohort barrier);
  * per-request metrics (TTFT, time-to-last-token, mean per-token
    latency, KV bytes pulled) straight off the handle;
  * hedged prefill dispatch (``hedge=2``): twin prefills race, the
    primary's COMPLETE aborts the loser and frees its slab;
  * prefix-affinity routing: a repeat prefix lands on the decode worker
    still holding it.

    PYTHONPATH=src python examples/serve_streaming.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.serving.disagg import DisaggService


def main() -> None:
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    print("== streaming handles: tokens as they land, not when the batch ends ==")
    svc = DisaggService(model, params, n_prefill=2, n_decode=2, num_blocks=128)
    h = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32),
                   max_new=6)
    print(f"  {h.request_id}: status={h.status.value} tokens={h.next_tokens()}")
    while not h.finished:
        svc.loop.tick()
        fresh = h.next_tokens()
        if fresh:
            print(f"  {h.request_id}: status={h.status.value} +{fresh}")
    m = h.metrics
    print(f"  done: ttft={m.ttft_s*1e3:.1f}ms ttlt={m.ttlt_s*1e3:.1f}ms "
          f"tbt={m.tbt_s*1e3:.1f}ms kv_pulled={m.kv_bytes_pulled/2**10:.0f}KiB")

    print("== continuous batching: B joins while A is mid-decode ==")
    ha = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32),
                    max_new=8)
    while ha.decoded < 4:
        svc.loop.tick()
    hb = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32),
                    max_new=2)
    svc.loop.run_until_idle()
    joined_early = hb.metrics.token_times[1] < ha.metrics.last_token_at
    print(f"  A finished with {ha.decoded} tokens; B submitted mid-decode, "
          f"first decode token before A finished: {joined_early}")

    print("== hedged prefill: twin dispatched, loser freed at COMPLETE ==")
    hh = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32),
                    max_new=4, hedge=2)
    twin = svc.hedges.get(hh.request_id)
    print(f"  primary={hh.prefill_worker} twin={twin.worker_id if twin else None}")
    out = hh.result()
    print(f"  tokens={out}; hedged={hh.metrics.hedged} "
          f"twin_freed={hh.request_id not in svc.hedges}")

    print("== prefix-affinity routing ==")
    svc2 = DisaggService(model, params, n_prefill=1, n_decode=2,
                         num_blocks=128, policy="prefix_affinity")
    shared = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    h1 = svc2.submit(shared, prefix_id="system-prompt", max_new=2)
    h1.result()
    h2 = svc2.submit(shared, prefix_id="system-prompt", max_new=2)
    print(f"  first -> decode@{h1.decode_worker}; repeat prefix -> "
          f"decode@{h2.decode_worker} (affinity hit: "
          f"{h1.decode_worker == h2.decode_worker})")
    h2.result()


if __name__ == "__main__":
    main()
