"""Cluster-scale what-if: pull vs push vs colocated under load.

Runs the calibrated discrete-event simulator (the same one behind the
paper-figure benchmarks) on the paper's model/hardware, printing the
latency/TTFT/TBT trade-offs at a saturating QPS.

    PYTHONPATH=src python examples/pull_vs_push_sim.py
"""
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import SHAREGPT, sample_requests


def main() -> None:
    cfg = get_config("mistral-large-123b")
    cost = CostModel(cfg, H100_NODE)
    print(f"model: {cfg.describe()}")
    print(f"worker: {H100_NODE.name}; KV capacity "
          f"{cost.kv_capacity_tokens()/1e6:.2f}M tokens; "
          f"prefill(20K) = {cost.prefill_s(20_471):.2f}s; "
          f"KV transfer(20K) = {cost.transfer_s(20_471)*1e3:.0f} ms (KVDirect) "
          f"vs {cost.transfer_s(20_471, mode='message')*1e3:.0f} ms (message)")

    qps = 0.9
    reqs = sample_requests(SHAREGPT, qps=qps, duration_s=240, seed=11)
    print(f"\nShareGPT-like workload @ {qps} QPS, {len(reqs)} requests:")
    for mode, workers in (("pull", (1, 1)), ("push", (1, 1)), ("colocated", (1, 1))):
        sim = ClusterSim(cost, SimConfig(n_prefill=workers[0], n_decode=workers[1],
                                         mode=mode))
        s = sim.run(list(reqs)).summary()
        print(f"  {mode:10s} p50={s['p50_total_s']:6.1f}s p90={s['p90_total_s']:6.1f}s "
              f"ttft_p90={s['p90_ttft_s']:5.1f}s tbt_p90={s['p90_tbt_s']*1e3:5.1f}ms")


if __name__ == "__main__":
    main()
