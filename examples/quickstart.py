"""Quickstart — the KVDirect core in 60 lines.

Builds two workers with real paged-KV address spaces, CONNECTs them
(descriptor exchange), TRANSFERs a request's blocks with coalesced
one-sided reads, COMPLETEs, and verifies the bytes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.connection import ChipInfo, ConnectionManager, DescriptorRegistry, WorkerInfo
from repro.core.pull_push import pull_kv
from repro.core.transfer_engine import TransferEngine
from repro.serving.blocks import BlockPool
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request


def main() -> None:
    # --- two workers, each with a registered KV slab -------------------
    pre = PagedKVCache("prefill0", num_layers=4, num_blocks=64, block_size=32,
                       kv_heads=8, head_dim=128)
    dec = PagedKVCache("decode0", num_layers=4, num_blocks=64, block_size=32,
                       kv_heads=8, head_dim=128, base_address=0x7F80000000)

    engine = TransferEngine(coalescing="sorted")   # beyond-paper coalescer
    engine.register_memory(pre.memory_region())
    engine.register_memory(dec.memory_region())
    engine.on_complete(lambda c: print(f"  COMPLETE({c.request_id}) → prefill frees blocks"))

    # --- CONNECT(): descriptor exchange (Fig. 5) ------------------------
    registry = DescriptorRegistry("prefill0")
    for desc in pre.descriptors():
        registry.register(desc)
    cm = ConnectionManager(WorkerInfo("decode0", "decode", "host-d0",
                                      (ChipInfo(0, "ici://d0/0"),)))
    conn = cm.connect(WorkerInfo("prefill0", "prefill", "host-p0",
                                 (ChipInfo(0, "ici://p0/0"),)), registry)
    d = conn.desc("layer0/kv")
    print(f"CONNECT: got {len(conn.descriptors)} descriptors; layer0 = "
          f"addr={d.address:#x} dims={d.dims} shape={d.shape} stride={d.stride}")

    # --- a 'prefilled' request: fill 8 blocks with known KV -------------
    pool_p, pool_d = BlockPool(64), BlockPool(64)
    req = Request("r1", prompt_len=8 * 32, max_new_tokens=16)
    req.prefill_blocks = pool_p.allocate(8)
    rng = np.random.default_rng(0)
    for layer in range(4):
        for b in req.prefill_blocks:
            pre.write_block(layer, b, rng.standard_normal((32, 8, 128)),
                            rng.standard_normal((32, 8, 128)))

    # --- TRANSFER + COMPLETE: pull-mode, one-sided ----------------------
    stats = pull_kv(req, conn=conn, engine=engine, decode_pool=pool_d,
                    decode_cache=dec)
    print(f"TRANSFER: {stats.txns_submitted} block-span transactions → "
          f"{stats.reads_posted} coalesced reads "
          f"({stats.coalesce_factor:.0f}× coalescing), "
          f"{stats.bytes_moved / 2**20:.1f} MiB moved, "
          f"modeled {stats.modeled_time_s * 1e6:.0f} µs on a 400 Gbps link")

    # --- verify ----------------------------------------------------------
    for layer in range(4):
        for pb, db in zip(req.prefill_blocks, req.decode_blocks):
            k_src, v_src = pre.read_block(layer, pb)
            k_dst, v_dst = dec.read_block(layer, db)
            assert np.array_equal(k_src, k_dst) and np.array_equal(v_src, v_dst)
    print("VERIFY: decode worker's KV is bit-identical. ✓")


if __name__ == "__main__":
    main()
