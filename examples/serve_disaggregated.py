"""End-to-end disaggregated serving with a real model (reduced config).

Prefill workers run real JAX prefill; KV blocks move through the
KVDirect engine (one-sided, coalesced); the decode worker batch-decodes.
Also demonstrates elastic scale-up and crash recovery.

    PYTHONPATH=src python examples/serve_disaggregated.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.serving.disagg import DisaggService


def main() -> None:
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    svc = DisaggService(model, params, n_prefill=2, num_blocks=128)
    rng = np.random.default_rng(0)

    print("== batched requests through the disaggregated pipeline ==")
    for i in range(3):
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        req = svc.submit(tokens)
        out = svc.generate(req, max_new=6)
        print(f"  {req.request_id}: prefill@{req.prefill_worker} → tokens {out}")
    s = svc.engine.stats
    print(f"  engine: {s.txns_submitted} txns → {s.reads_posted} reads "
          f"(coalesce {s.coalesce_factor:.1f}×), {s.bytes_moved/2**20:.1f} MiB")

    print("== elastic scale-up: add a prefill worker to the RUNNING cluster ==")
    wid = svc.add_prefill_worker(num_blocks=128)
    print(f"  {wid} joined; decode worker auto-CONNECTed: peers={svc.conn_mgr.peers}")

    print("== crash recovery: kill the prefill worker mid-request ==")
    tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    req = svc.submit(tokens)
    victim = req.prefill_worker
    svc.fail_prefill_worker(victim)
    print(f"  {victim} failed after prefill; re-prefilled on {req.prefill_worker} "
          f"(retries={req.retries})")
    out = svc.generate(req, max_new=6)
    print(f"  {req.request_id}: recovered → tokens {out}")


if __name__ == "__main__":
    main()
